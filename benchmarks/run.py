"""Benchmark runner: one function per paper table.

Prints ``name,us_per_call,derived`` CSV per kernel plus per-table averages,
and writes the aggregate JSON next to the dry-run results.

  PYTHONPATH=src python -m benchmarks.run [--tables 1,2,3,4] [--full]

``--full`` (or REPRO_BENCH_FULL=1) uses the paper's parameters
(D=6/10, N=3/5, R=30, k=3); default CI mode keeps the suite minutes-scale.
A shared PatternStore flows Table1 -> Table2 -> Table3 -> Table4, reproducing
the paper's cross-kernel and cross-platform Performance Pattern
Inheritance.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tables", default="1,2,3,4")
    ap.add_argument("--full", action="store_true",
                    help="paper iteration parameters (slow)")
    ap.add_argument("--out", default="results/bench.json")
    args = ap.parse_args()
    if args.full:
        os.environ["REPRO_BENCH_FULL"] = "1"

    from repro.core import PatternStore
    from benchmarks import (table1_polybench_a, table2_polybench_b,
                            table3_appsdk, table4_hotspots)

    store = PatternStore(os.path.join(os.path.dirname(args.out) or ".",
                                      "patterns.json")
                         if args.out else None)
    tables = {
        "1": ("table1_polybench_a", table1_polybench_a.main),
        "2": ("table2_polybench_b", table2_polybench_b.main),
        "3": ("table3_appsdk", table3_appsdk.main),
        "4": ("table4_hotspots", table4_hotspots.main),
    }
    results = {}
    t0 = time.time()
    for tid in args.tables.split(","):
        name, fn = tables[tid.strip()]
        print(f"== {name} ==", flush=True)
        results[name] = fn(store)
    results["wall_s"] = round(time.time() - t0, 1)
    results["patterns_learned"] = len(store)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"# done in {results['wall_s']}s; patterns learned: {len(store)}")


if __name__ == "__main__":
    main()
