"""Shared benchmark machinery: one function per paper table.

Each table runs the full MEP pipeline per kernel and reports the paper's
three indicators: Standalone speedup (in the MEP), Integrated speedup
(kernel reinstalled in the application / composite context), and Direct
LLM Optimization (one-shot, no feedback loop).

CSV rows: ``name,us_per_call,derived`` where ``us_per_call`` is the
optimized kernel's trimmed-mean time and ``derived`` carries the speedups.
``--full`` uses the paper's parameters (D=6/10, N=3/5, R=30, k=3); the
default CI mode shrinks R/D so the whole suite stays minutes-scale.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import (CPUPlatform, DirectProposer, HeuristicProposer,
                        MEPConstraints, OptConfig, PatternStore,
                        TPUModelPlatform, build_mep, get_case, optimize)

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def params_for(suite: str):
    """Paper's iteration parameters: PolyBench D=6,N=3; others D=10,N=5;
    R=30,k=3.  CI mode: R=5,k=1 and half the rounds."""
    d, n = (6, 3) if suite == "polybench" else (10, 5)
    if FULL:
        return OptConfig(d_rounds=d, n_candidates=n, r=30, k=3), \
            MEPConstraints(r=30, k=3, t_max_s=30.0)
    return OptConfig(d_rounds=max(2, d // 2), n_candidates=n, r=5, k=1), \
        MEPConstraints(r=5, k=1, t_max_s=2.0)


@dataclass
class Row:
    name: str
    us_per_call: float
    standalone: float
    integrated: Optional[float]
    direct: float

    def csv(self) -> str:
        integ = f"{self.integrated:.2f}" if self.integrated else ""
        return (f"{self.name},{self.us_per_call:.2f},"
                f"standalone={self.standalone:.2f}x integrated={integ}x "
                f"direct={self.direct:.2f}x")


def run_suite(suite: str, platform, store: PatternStore, *,
              integrated_fn=None, seed: int = 0) -> List[Row]:
    cfg, cons = params_for(suite)
    rows: List[Row] = []
    for case in _suite_cases(suite):
        mep = build_mep(case, platform, constraints=cons, seed=seed)
        res = optimize(case, platform, HeuristicProposer(seed, store,
                                                         platform.name),
                       cfg=cfg, constraints=cons, patterns=store, mep=mep)
        direct = optimize(case, platform, DirectProposer(),
                          cfg=OptConfig(d_rounds=1, n_candidates=1,
                                        r=cfg.r, k=cfg.k),
                          constraints=cons, mep=mep)
        integ = integrated_fn(case, res) if integrated_fn else None
        rows.append(Row(case.name, res.best_time_s * 1e6, res.speedup,
                        integ, direct.speedup))
        print(rows[-1].csv(), flush=True)
    return rows


def _suite_cases(suite: str):
    from repro.core import cases
    return cases(suite)


def summarize(table: str, rows: List[Row]) -> Dict:
    import numpy as np
    avg = lambda xs: float(np.mean([x for x in xs if x])) if any(xs) else 0.0
    rec = {
        "table": table,
        "avg_standalone": avg([r.standalone for r in rows]),
        "avg_integrated": avg([r.integrated for r in rows]),
        "avg_direct": avg([r.direct for r in rows]),
        "rows": [r.csv() for r in rows],
    }
    print(f"# {table}: avg standalone {rec['avg_standalone']:.2f}x, "
          f"integrated {rec['avg_integrated']:.2f}x, "
          f"direct {rec['avg_direct']:.2f}x", flush=True)
    return rec
