"""Shared benchmark machinery: one function per paper table, all driven
through the campaign engine.

Each table submits its whole suite to a ``Campaign`` — the heuristic
(iterative, paper §3.2) and direct (one-shot baseline) jobs for every
kernel — and reports the paper's three indicators: Standalone speedup
(in the MEP), Integrated speedup (kernel reinstalled in the application
/ composite context), and Direct LLM Optimization (one-shot, no feedback
loop).  A ``BenchContext`` threads the shared PatternStore, EvalCache,
and ResultsDB through the tables, so cross-table Performance Pattern
Inheritance and cross-run evaluation caching both happen automatically.

CSV rows: ``name,us_per_call,derived`` where ``us_per_call`` is the
optimized kernel's trimmed-mean time and ``derived`` carries the speedups.
``--full`` uses the paper's parameters (D=6/10, N=3/5, R=30, k=3); the
default CI mode shrinks R/D so the whole suite stays minutes-scale.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import (Campaign, CaseJob, DirectProposer, EvalCache,
                        HeuristicProposer, MeasureConfig, MEPConstraints,
                        OptConfig, PatternStore, PopulationConfig,
                        ResultsDB)

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def params_for(suite: str):
    """Paper's iteration parameters: PolyBench D=6,N=3; others D=10,N=5;
    R=30,k=3.  CI mode: R=5,k=1 and half the rounds."""
    d, n = (6, 3) if suite == "polybench" else (10, 5)
    if FULL:
        return OptConfig(d_rounds=d, n_candidates=n, r=30, k=3), \
            MEPConstraints(r=30, k=3, t_max_s=30.0)
    return OptConfig(d_rounds=max(2, d // 2), n_candidates=n, r=5, k=1), \
        MEPConstraints(r=5, k=1, t_max_s=2.0)


@dataclass
class BenchContext:
    """Shared campaign state flowing through the tables."""
    store: PatternStore
    cache: Optional[EvalCache] = None
    db: Optional[ResultsDB] = None
    max_workers: Optional[int] = None
    executor: Optional[str] = None   # inprocess | subprocess | local-cluster
    measure: Optional[MeasureConfig] = None   # adaptive-engine policy
    serve_slots: Optional[int] = None         # table 9: KV slot pool size
    serve_buckets: Optional[List[int]] = None  # table 9: prefill buckets
    # population-search policy (table 11; None → each table's default /
    # the greedy loop elsewhere)
    population: Optional[PopulationConfig] = None

    def campaign(self, platform) -> Campaign:
        # --workers applies to measured platforms too: their wall-clock
        # slices serialize on the campaign's timing lease, so fan-out no
        # longer threatens eq. 3
        return Campaign(platform, patterns=self.store, cache=self.cache,
                        db=self.db, max_workers=self.max_workers,
                        executor=self.executor, measure=self.measure,
                        verbose=True)


def ensure_ctx(ctx) -> BenchContext:
    """Accept a BenchContext, a bare PatternStore (legacy call sites), or
    None (standalone table run)."""
    if ctx is None:
        return BenchContext(PatternStore())
    if isinstance(ctx, PatternStore):
        return BenchContext(ctx)
    return ctx


@dataclass
class Row:
    name: str
    us_per_call: float
    standalone: float
    integrated: Optional[float]
    direct: float
    cache_hits: int = 0

    def csv(self) -> str:
        integ = f"{self.integrated:.2f}" if self.integrated else ""
        return (f"{self.name},{self.us_per_call:.2f},"
                f"standalone={self.standalone:.2f}x integrated={integ}x "
                f"direct={self.direct:.2f}x")


def run_suite(suite: str, platform, ctx, *,
              integrated_fn=None, seed: int = 0) -> List[Row]:
    ctx = ensure_ctx(ctx)
    cfg, cons = params_for(suite)
    direct_cfg = OptConfig(d_rounds=1, n_candidates=1, r=cfg.r, k=cfg.k,
                           fe_input_sets=cfg.fe_input_sets)
    suite_cases = _suite_cases(suite)
    jobs: List[CaseJob] = []
    for case in suite_cases:
        jobs.append(CaseJob(case, HeuristicProposer(seed, ctx.store,
                                                    platform.name),
                            cfg=cfg, constraints=cons, seed=seed))
        jobs.append(CaseJob(case, DirectProposer(), cfg=direct_cfg,
                            constraints=cons, seed=seed,
                            label=f"{case.name}#direct"))
    results = ctx.campaign(platform).run(jobs)
    rows: List[Row] = []
    for i, case in enumerate(suite_cases):
        res, direct = results[2 * i], results[2 * i + 1]
        integ = integrated_fn(case, res) if integrated_fn else None
        rows.append(Row(case.name, res.best_time_s * 1e6, res.speedup,
                        integ, direct.speedup,
                        cache_hits=res.cache_hits + direct.cache_hits))
        print(rows[-1].csv(), flush=True)
    return rows


def _suite_cases(suite: str):
    from repro.core import cases
    return cases(suite)


def summarize(table: str, rows: List[Row]) -> Dict:
    import numpy as np
    avg = lambda xs: float(np.mean([x for x in xs if x])) if any(xs) else 0.0
    rec = {
        "table": table,
        "avg_standalone": avg([r.standalone for r in rows]),
        "avg_integrated": avg([r.integrated for r in rows]),
        "avg_direct": avg([r.direct for r in rows]),
        "cache_hits": int(sum(r.cache_hits for r in rows)),
        "rows": [r.csv() for r in rows],
    }
    print(f"# {table}: avg standalone {rec['avg_standalone']:.2f}x, "
          f"integrated {rec['avg_integrated']:.2f}x, "
          f"direct {rec['avg_direct']:.2f}x", flush=True)
    return rec
