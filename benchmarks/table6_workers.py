"""Table 6 — the worker-fabric demonstration (not a paper table).

Two claims about the out-of-process evaluation fabric, on analytic
(TPU-model) platform cases so every number is deterministic:

1. **Equivalence** — a campaign run with ``SubprocessExecutor`` produces
   byte-identical winner records to the in-process run: same cases, same
   seeds, and (for the replay leg) the same shared cache file.  The
   comparison canonicalizes each ``case_result`` down to the fields the
   search determines — variants, times, speedup, rounds, stop reason —
   and compares the serialized bytes.
2. **Scaling** — with N workers the same campaign finishes faster than
   ``max_workers=1``, because each worker process evaluates FE checks and
   jit builds under its own GIL.

Output JSON (written into the aggregate ``--out`` and, standalone, to
``results/workers_demo.json``) carries both wall-clocks, the speedup,
and the equivalence verdicts, plus the host's core count — the scaling
ceiling is ``min(workers, cores)``.

    PYTHONPATH=src python -m benchmarks.run --tables 6 --workers 4
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List, Optional

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, EvalCache, HeuristicProposer,
                        InProcessExecutor, MEPConstraints, OptConfig,
                        ResultsDB, SubprocessExecutor, TPUModelPlatform,
                        get_case)

CASES = ["2mm", "3mm", "atax", "bicg", "corr", "covar", "gemm", "gemver",
         "gesummv", "gramschm", "syr2k", "syrk"]
CFG = OptConfig(d_rounds=5, n_candidates=4, r=5, k=1)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0

# fields of a case_result the search determines deterministically —
# everything else (wall-clock, timestamps, cache hits) varies run to run
WINNER_FIELDS = ("job", "case", "platform", "proposer", "baseline_time_s",
                 "best_time_s", "best_variant", "speedup", "rounds",
                 "stop_reason")


def _jobs() -> List[CaseJob]:
    # fresh proposers per run: the demo's determinism rests on each run
    # seeing the identical seeded RNG stream (and no shared PatternStore)
    return [CaseJob(get_case(name), HeuristicProposer(SEED),
                    cfg=CFG, constraints=CONS, seed=SEED)
            for name in CASES]


def winner_records(db: ResultsDB) -> List[bytes]:
    recs = sorted(db.records("case_result"), key=lambda r: r["case"])
    return [json.dumps({k: r.get(k) for k in WINNER_FIELDS},
                       sort_keys=True).encode()
            for r in recs]


def _run(tag: str, executor, cache_path: str, db_path: str) -> Dict:
    cache = EvalCache(cache_path)
    db = ResultsDB(db_path)
    camp = Campaign(TPUModelPlatform(), cache=cache, db=db,
                    executor=executor)
    if hasattr(executor, "warm"):
        # a production fabric (LocalClusterExecutor / autotuner) keeps
        # workers alive across campaigns, so spawn+import is paid once,
        # not per campaign — warm outside the timed region to match
        executor.warm()
    t0 = time.time()
    c0 = sum(os.times()[:2])
    results = camp.run(_jobs())
    wall = time.time() - t0
    own_cpu = sum(os.times()[:2]) - c0
    print(f"#   {tag}: {wall:.1f}s wall, "
          f"{sum(len(r.rounds) for r in results)} rounds total", flush=True)
    return {"wall_s": round(wall, 2), "db": db,
            "scheduler_cpu_s": round(own_cpu, 2),
            "speedups": {r.case_name: round(r.speedup, 4)
                         for r in results}}


def main(ctx=None, *, workers: Optional[int] = None) -> Dict:
    ctx = ensure_ctx(ctx)
    if workers is None:
        workers = ctx.max_workers or 4
    cpus = os.cpu_count() or 1
    tmp = tempfile.mkdtemp(prefix="workers_demo_")
    print(f"# worker-fabric demo: {len(CASES)} analytic cases, "
          f"subprocess workers={workers}, cpus={cpus}", flush=True)

    # leg A: the reference — in-process, one worker, cold cache
    ref = _run("inprocess max_workers=1", InProcessExecutor(1),
               os.path.join(tmp, "cache_a.jsonl"),
               os.path.join(tmp, "db_a.jsonl"))
    # cold-cache fan-out at each width: the scaling curve is bounded by
    # min(workers, cpus) — beyond the core count, extra workers only
    # oversubscribe — so measure both the core-matched and the
    # requested width when they differ
    widths = sorted({min(workers, cpus), workers})
    fans = {}
    for w in widths:
        fans[w] = _run(f"subprocess workers={w}", SubprocessExecutor(w),
                       os.path.join(tmp, f"cache_b{w}.jsonl"),
                       os.path.join(tmp, f"db_b{w}.jsonl"))
    fan = fans[workers]
    # leg C: subprocess against leg A's cache file — the shared-cache
    # replay the acceptance criterion names ("same cache file")
    shared = _run(f"subprocess workers={workers} (shared cache)",
                  SubprocessExecutor(workers),
                  os.path.join(tmp, "cache_a.jsonl"),
                  os.path.join(tmp, "db_c.jsonl"))

    ref_w = winner_records(ref["db"])
    identical_cold = all(winner_records(f["db"]) == ref_w
                         for f in fans.values())
    identical_shared = winner_records(shared["db"]) == ref_w
    speedup = ref["wall_s"] / max(fan["wall_s"], 1e-9)
    best_w = min(fans, key=lambda w: fans[w]["wall_s"])
    best_speedup = ref["wall_s"] / max(fans[best_w]["wall_s"], 1e-9)
    replay_speedup = ref["wall_s"] / max(shared["wall_s"], 1e-9)
    rec = {
        "table": "table6_workers",
        "cases": CASES,
        "workers": workers,
        "cpus": cpus,
        # the serial reference is not single-core: XLA compiles with its
        # own thread pool, so the fan-out ceiling on this host is
        # cpus / serial_core_utilization, not `workers`
        "serial_core_utilization": round(
            ref["scheduler_cpu_s"] / max(ref["wall_s"], 1e-9), 2),
        "wall_s_inprocess_1": ref["wall_s"],
        "wall_s_subprocess": {str(w): fans[w]["wall_s"] for w in fans},
        "wall_s_subprocess_shared_cache": shared["wall_s"],
        "fabric_speedup": round(speedup, 2),
        "fabric_speedup_best": {"workers": best_w,
                                "speedup": round(best_speedup, 2)},
        "shared_cache_replay_speedup": round(replay_speedup, 2),
        "winners_identical_cold_cache": identical_cold,
        "winners_identical_shared_cache": identical_shared,
        "case_speedups": ref["speedups"],
    }
    print(f"# table6_workers: fabric speedup {speedup:.2f}x cold at "
          f"workers={workers} (best {best_speedup:.2f}x at "
          f"workers={best_w}), {replay_speedup:.2f}x shared-cache replay, "
          f"on {cpus} cores (serial already uses "
          f"{rec['serial_core_utilization']} cores); winners identical: "
          f"cold={identical_cold} shared={identical_shared}", flush=True)
    for leg in [ref, shared] + list(fans.values()):
        leg.pop("db", None)
    rec["legs"] = {"inprocess_1": ref,
                   **{f"subprocess_{w}": fans[w] for w in fans},
                   "subprocess_shared": shared}
    out = os.path.join("results", "workers_demo.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
