"""Paper Table 3 — APP SDK suite (D=10, N=5) on both platforms; reported
numbers follow the paper's DCU platform = our TPU model, with the measured
CPU loop as the secondary check."""
from __future__ import annotations

from benchmarks.common import ensure_ctx, run_suite, summarize
from repro.core import CPUPlatform, TPUModelPlatform


def main(ctx=None):
    ctx = ensure_ctx(ctx)
    rows = run_suite("appsdk", TPUModelPlatform(), ctx)
    rec = summarize("table3_appsdk_platformB", rows)
    rows_cpu = run_suite("appsdk", CPUPlatform(), ctx)
    rec_cpu = summarize("table3_appsdk_platformA", rows_cpu)
    rec["platformA"] = rec_cpu
    return rec


if __name__ == "__main__":
    main()
