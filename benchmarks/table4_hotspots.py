"""Paper Table 4 — hotspot kernels from the large-scale application.

The application is our multi-pod training stack; the kernels are its
attention / RWKV-WKV / Mamba-SSD / MoE grouped-GEMM hotspots.  Standalone
speedup comes from the MEP loop; Integrated speedup reinstalls the winner
at its ops-registry site and wall-clocks a real (reduced-config) train
forward — exactly the paper's "optimized variants are reintegrated into
the original application for validation".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import ensure_ctx, run_suite, summarize
from repro.core import CPUPlatform, TPUModelPlatform, integrate
from repro.configs import get_config
from repro.models import get_model

_APP_ARCH = {
    "attention_prefill": "glm4-9b",
    "rwkv_wkv": "rwkv6-7b",
    "mamba_ssd": "hymba-1.5b",
    "moe_grouped_gemm": "qwen2-moe-a2.7b",
}


def _app_context(arch: str):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              param_dtype="float32")
    model = get_model(cfg, q_chunk=32)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)

    def make_step():
        def step(params, toks):
            h, _, _ = model.forward(params, toks)
            return jnp.sum(h)
        return step

    return make_step, (params, toks)


def integrated_fn(case, res):
    if case.name == "moe_grouped_gemm":
        # the grouped-GEMM site is exercised through the MoE block's dense
        # einsums; integration measured standalone-in-context instead
        return None
    make_step, args = _app_context(_APP_ARCH[case.name])
    ir = integrate.integrated_speedup(case, res.best_variant, make_step,
                                      args, r=5, k=1)
    assert ir.fe_ok, f"{case.name}: integration broke FE ({ir.max_abs_err})"
    return ir.integrated_speedup


def main(ctx=None):
    ctx = ensure_ctx(ctx)
    # Paper protocol: standalone and integrated are measured on the SAME
    # platform.  Platform A (CPU) actually executes the application, so its
    # winners are what we reinstall and validate end-to-end; Platform B
    # (TPU model) gives the target-hardware standalone row.
    rows_a = run_suite("hpc", CPUPlatform(), ctx,
                       integrated_fn=integrated_fn)
    rec = summarize("table4_hpc_hotspots_platformA", rows_a)
    rows_b = run_suite("hpc", TPUModelPlatform(), ctx)
    rec_b = summarize("table4_hpc_hotspots_platformB_standalone", rows_b)
    rec["platformB_standalone"] = rec_b
    return rec


if __name__ == "__main__":
    main()
