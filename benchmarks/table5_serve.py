"""Serve-layer autotune benchmark: the online loop measured end to end.

Replays a synthetic traffic trace (mixed prefill/decode at several
context lengths per hotspot site) into the per-site telemetry, then runs
``ServeAutotuner`` cycles against it:

  cycle 1  — cold: campaigns at the traffic-weighted scales, guarded
             installs of every winner
  cycle 2  — warm: identical traffic; must resolve to a cached no-op

CSV rows: ``site@scale,us_per_call,campaign=..x guard=..`` — the
campaign speedup is the analytic standalone gain, the guard column
records the guarded-install outcome (installed / rolled_back / reason).
"""
from __future__ import annotations

import time

from benchmarks.common import ensure_ctx
from repro.core import TPUModelPlatform
from repro.kernels import ops
from repro.serve import AutotuneConfig, ServeAutotuner

# (site, prompt_len, decode_tokens, requests) — a plausible serving mix:
# chat-style short prompts with long decodes plus a long-context batch
TRACE = [
    ("attention", 256, 128, 24),
    ("attention", 1024, 32, 8),
    ("rwkv_wkv", 256, 96, 16),
    ("ssm_chunk", 512, 64, 12),
    ("moe_gemm", 128, 64, 16),
]


def replay_trace(telemetry: ops.Telemetry) -> int:
    total = 0
    for site, prompt, decode, requests in TRACE:
        for r in range(requests):
            telemetry.observe(site, scale=prompt, tokens=prompt,
                              kind="prefill")
            for d in range(decode):
                telemetry.observe(site, scale=prompt + d, tokens=1,
                                  kind="decode")
            total += prompt + decode
    return total


def main(ctx=None):
    ctx = ensure_ctx(ctx)
    telemetry = ops.Telemetry()
    tokens = replay_trace(telemetry)
    tuner = ServeAutotuner(
        TPUModelPlatform(),
        config=AutotuneConfig(min_tokens=1, max_sites=len(TRACE),
                              probe_r=2, probe_k=0,
                              # analytic campaign metric, wall-clock guard:
                              # generous regression bound for CI machines
                              max_regression=20.0),
        cache=ctx.cache, db=ctx.db, patterns=ctx.store,
        telemetry=telemetry, verbose=True)

    t0 = time.time()
    cold = tuner.run_once()
    cold_s = time.time() - t0
    rows = []
    for res, (site, scale) in zip(cold.results, cold.hot.items()):
        swap = next((s for s in cold.swaps if s.site == site), None)
        guard = ("installed" if swap and swap.active else
                 swap.reason if swap else "not_attempted")
        row = (f"{site}@{scale},{res.best_time_s * 1e6:.2f},"
               f"campaign={res.speedup:.2f}x guard={guard}")
        rows.append(row)
        print(row, flush=True)

    t0 = time.time()
    warm = tuner.run_once()      # same traffic → tuned-scale no-op
    warm_s = time.time() - t0

    rec = {
        "table": "table5_serve_autotune",
        "trace_tokens": tokens,
        "hot_sites": cold.hot,
        "avg_campaign_speedup": (
            sum(r.speedup for r in cold.results) / len(cold.results)
            if cold.results else 0.0),
        "installed": [s.site for s in cold.installed],
        "rolled_back": [s.site for s in cold.rolled_back],
        "cold_cycle_s": round(cold_s, 3),
        "warm_cycle_s": round(warm_s, 3),
        "warm_noop": not warm.hot,
        "rows": rows,
    }
    print(f"# table5_serve_autotune: {len(cold.installed)} installed, "
          f"{len(cold.rolled_back)} rolled back, cold {cold_s:.2f}s → "
          f"warm {warm_s:.3f}s", flush=True)
    ops.clear_all()              # leave no installs behind for later tables
    return rec


if __name__ == "__main__":
    main()
