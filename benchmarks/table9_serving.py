"""Table 9: continuous-batching serving engine, old vs new.

Drives the same mixed-length, Poisson-ish arrival trace through both
serving engines over a real (reduced) LM:

  old — ``FixedBatchServer``: single shared decode position, one prefill
        device call per request, every prompt padded to the global
        ``prompt_len`` (the longest prompt in the trace — the engine's
        documented contract for mixed traffic).
  new — ``BatchedServer``: ragged per-slot decode, bucketed packed
        prefill (one call per bucket per admission wave), per-bucket AOT
        executables built at startup.

Reported per engine: serving wall, total and decode-only tokens/s,
p50/p99 inter-token latency (wall time of the step that produced each
token), and mean slot occupancy.  The new engine's greedy outputs are
additionally checked token-for-token against the fixed-batch
``generate()`` reference for every request — the speedup only counts if
serving stays exact.

CSV rows: ``engine,us_per_token,tokens/s + latency + occupancy``.
Knobs: ``--slots`` / ``--buckets`` (benchmarks.run) size the pool and
override the power-of-two bucket ladder.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence

import numpy as np

from benchmarks.common import ensure_ctx

# CI-mode trace: mostly chat-style short prompts with a long-context
# tail, mixed decode budgets, bursty arrivals.  --full doubles it.
N_REQUESTS = 36
MAX_LEN = 160
SHORT, LONG = (6, 18), (72, 120)
LONG_FRAC = 0.25
MAX_NEW = (4, 14)


def build_trace(n: int, seed: int = 0):
    """[(prompt, max_new, arrival_step)] — arrivals are cumulative
    Poisson gaps, so requests come in ragged bursts, not lock-step."""
    rng = np.random.default_rng(seed)
    out, step = [], 0
    for _ in range(n):
        lo, hi = LONG if rng.random() < LONG_FRAC else SHORT
        plen = int(rng.integers(lo, hi + 1))
        prompt = rng.integers(1, 500, plen).astype(np.int32)
        max_new = int(rng.integers(*MAX_NEW))
        step += int(rng.poisson(0.7))
        out.append((prompt, max_new, step))
    return out


def drive(srv, trace) -> dict:
    """Serve the trace to completion, timing every step."""
    pending = deque(trace)
    reqs, tok_lat, occ = [], [], []
    steps = 0
    t_all = time.perf_counter()
    while pending or srv.queue or any(a is not None for a in srv.active):
        while pending and pending[0][2] <= steps:
            p, mn, _ = pending.popleft()
            reqs.append(srv.submit(p, max_new=mn))
        if (not srv.queue and all(a is None for a in srv.active)
                and pending):
            steps = pending[0][2]          # idle gap: jump to next arrival
            continue
        before = sum(len(r.tokens) for r in reqs)
        t0 = time.perf_counter()
        srv.step()
        dt = time.perf_counter() - t0
        emitted = sum(len(r.tokens) for r in reqs) - before
        # inter-token latency: every token emitted this step waited dt
        tok_lat.extend([dt] * emitted)
        occ.append(sum(a is not None for a in srv.active) / srv.slots)
        steps += 1
        if steps > 100_000:
            raise RuntimeError("serving loop did not drain")
    wall = time.perf_counter() - t_all
    total = sum(len(r.tokens) for r in reqs)
    decode = sum(max(0, len(r.tokens) - 1) for r in reqs)
    lat = np.asarray(tok_lat) if tok_lat else np.zeros(1)
    return {
        "requests": len(reqs),
        "wall_s": round(wall, 4),
        "tokens": total,
        "tokens_per_s": round(total / wall, 2),
        "decode_tokens_per_s": round(decode / wall, 2),
        "p50_ms_per_token": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "p99_ms_per_token": round(float(np.percentile(lat, 99)) * 1e3, 3),
        "slot_occupancy": round(float(np.mean(occ)) if occ else 0.0, 3),
        "_reqs": reqs,
    }


def check_equivalence(model, params, result, sample: int = 12) -> int:
    """Every sampled request served by the new engine must match the
    fixed-batch greedy reference token for token."""
    from repro.serve import generate
    import jax.numpy as jnp
    reqs = result["_reqs"]
    picked = reqs[:: max(1, len(reqs) // sample)]
    for r in picked:
        ref = generate(model, params, jnp.asarray(r.prompt[None, :]),
                       max_new=r.max_new)[0]
        got = r.tokens
        assert got == [int(t) for t in ref[:len(got)]], (
            f"request {r.rid} (len {len(r.prompt)}) diverged from "
            f"generate(): {got} vs {list(ref)}")
    return len(picked)


def main(ctx=None, *, slots: Optional[int] = None,
         buckets: Optional[Sequence[int]] = None, seed: int = 0):
    import os
    import jax
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serve import BatchedServer, FixedBatchServer

    ctx = ensure_ctx(ctx)
    slots = slots or getattr(ctx, "serve_slots", None) or 4
    buckets = buckets or getattr(ctx, "serve_buckets", None)
    full = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
    n = N_REQUESTS * (2 if full else 1)

    # a real (reduced) dense LM: attention cost grows with context, so
    # decoding short requests at fixed-padded positions is genuinely
    # more expensive than ragged decode at their true lengths
    cfg = dataclasses.replace(get_config("stablelm-3b").reduced(),
                              param_dtype="float32")
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = build_trace(n, seed=seed)
    longest = max(len(p) for p, _, _ in trace)

    # ---- old: fixed-batch, every prompt padded to the longest ----------
    pad_trace = [(np.pad(p, (0, longest - len(p))), mn, at)
                 for p, mn, at in trace]
    old_srv = FixedBatchServer(model, params, slots=slots,
                               prompt_len=longest,
                               max_len=longest + MAX_NEW[1] + 1)
    # untimed warmup: the old engine compiles lazily on first use; the
    # new one AOT-compiles at startup (reported separately) — warm both
    # sides so the timed comparison is pure serving
    drive(old_srv, pad_trace[:2])
    old = drive(old_srv, pad_trace)

    # ---- new: continuous batching ---------------------------------------
    t0 = time.perf_counter()
    new_srv = BatchedServer(model, params, slots=slots, max_len=MAX_LEN,
                            buckets=buckets)
    startup_s = time.perf_counter() - t0
    new = drive(new_srv, trace)
    checked = check_equivalence(model, params, new)

    speedup = new["decode_tokens_per_s"] / max(old["decode_tokens_per_s"],
                                               1e-9)
    rows = []
    for name, r in (("fixed_batch", old), ("continuous", new)):
        row = (f"{name},{1e6 / max(r['tokens_per_s'], 1e-9):.2f},"
               f"tokens/s={r['tokens_per_s']:.1f} "
               f"decode/s={r['decode_tokens_per_s']:.1f} "
               f"p50={r['p50_ms_per_token']:.2f}ms "
               f"p99={r['p99_ms_per_token']:.2f}ms "
               f"occ={r['slot_occupancy']:.2f}")
        rows.append(row)
        print(row, flush=True)
        r.pop("_reqs")

    rec = {
        "table": "table9_serving",
        "config": {"slots": slots, "requests": n, "longest_prompt": longest,
                   "buckets": list(new_srv.buckets), "max_len": MAX_LEN,
                   "full": full},
        "fixed_batch": old,
        "continuous": new,
        "aot": {"executables": new_srv.aot_compiles,
                "startup_s": round(startup_s, 3)},
        "decode_tokens_per_s_speedup": round(speedup, 2),
        "equivalence_checked_requests": checked,
        "rows": rows,
    }
    print(f"# table9_serving: decode {old['decode_tokens_per_s']:.1f} -> "
          f"{new['decode_tokens_per_s']:.1f} tok/s ({speedup:.2f}x), "
          f"{checked} requests greedy-exact vs generate(), "
          f"{new_srv.aot_compiles} AOT executables in {startup_s:.2f}s",
          flush=True)
    return rec


if __name__ == "__main__":
    import json
    import os
    os.makedirs("results", exist_ok=True)
    rec = main()
    with open("results/table9_serving.json", "w") as f:
        json.dump(rec, f, indent=1)
    print("# wrote results/table9_serving.json")
