"""Paper Table 2 — PolyBench on Platform B (TPU v5e analytic model), with
Performance Pattern Inheritance transferred from Platform A (the paper's
NVIDIA→DCU cross-platform transfer).

Standalone = modeled MEP speedup; Integrated = modeled speedup with the
launch-overhead context of the enclosing app step; Direct = one-shot.
Also reports rounds-to-best with and without PPI (the convergence
acceleration claim)."""
from __future__ import annotations

from benchmarks.common import ensure_ctx, params_for, run_suite, summarize
from repro.core import (HeuristicProposer, PatternStore, TPUModelPlatform,
                        optimize)


def integrated_fn(case, res):
    # modeled: integrated time adds the app-side launch context; ratio of
    # baseline/optimized within that context
    plat = TPUModelPlatform()
    scale = min(case.scales)
    ctx_overhead = 20e-6
    tb = plat.time_variant(case, res.baseline_variant, scale, None,
                           r=3, k=0).trimmed_mean_s + ctx_overhead
    to = plat.time_variant(case, res.best_variant, scale, None,
                           r=3, k=0).trimmed_mean_s + ctx_overhead
    return tb / to


def ppi_convergence(store: PatternStore):
    """Rounds needed to reach within 5% of the best time, with vs without
    inherited patterns (measures the paper's convergence acceleration)."""
    from repro.core import OptConfig, MEPConstraints, get_case
    plat = TPUModelPlatform()
    cfg, cons = params_for("polybench")
    out = {}
    for name in ("gemm", "syrk"):
        case = get_case(name)
        r_with = optimize(case, plat, HeuristicProposer(0, store, plat.name),
                          cfg=cfg, constraints=cons)
        r_wo = optimize(case, plat, HeuristicProposer(0, None, plat.name),
                        cfg=cfg, constraints=cons)

        def rounds_to_best(res):
            best = res.best_time_s * 1.05
            for rl in res.rounds:
                if rl.best_time_s <= best:
                    return rl.round + 1
            return len(res.rounds)

        out[name] = {"with_ppi": rounds_to_best(r_with),
                     "without_ppi": rounds_to_best(r_wo)}
        print(f"# ppi_convergence {name}: {out[name]}", flush=True)
    return out


def main(ctx=None):
    ctx = ensure_ctx(ctx)
    rows = run_suite("polybench", TPUModelPlatform(), ctx,
                     integrated_fn=integrated_fn)
    rec = summarize("table2_polybench_platformB", rows)
    rec["ppi_convergence"] = ppi_convergence(ctx.store)
    return rec


if __name__ == "__main__":
    main()
