"""Assemble EXPERIMENTS.md: splice generated §Dry-run/§Roofline tables and
the §Perf hillclimb log into the placeholders.

    PYTHONPATH=src python benchmarks/assemble_experiments.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.report import dryrun_table, load, roofline_table

PERF_NARRATIVE = r"""
Cells chosen from the baseline table: **A = dbrx-132b × train_4k** (worst
MFU bound of the compute-heavy cells; most collective-bound; representative
of the MoE family), **B = glm4-9b × prefill_32k** (most representative of
the paper's attention-kernel technique at system level), **C = hymba-1.5b ×
prefill_32k** (worst useful-flops ratio, memory-bound).  Full records:
`results/hillclimb.jsonl`.

### Cell A — dbrx-132b × train_4k (baseline 58.22 s, MFU bound 0.078)

| iter | hypothesis (napkin math) | change | step before → after | verdict |
|---|---|---|---|---|
| A1 | dominant AR is the expert output [B,E,C,d]; capacity ≈ 5× tokens (top-4 @ cf 1.25), so gathering per-token slots BEFORE the cross-f reduction shrinks the psum 5× | shard_map combine-before-reduce | 58.22 → 35.67 s | **confirmed** (1.63×) |
| A2 | with the MoE psum activation-sized, SP's per-block seq AG/RS now costs more than it saves | disable seq sharding | 35.67 → 22.53 s but 21.9 GiB > HBM | partially confirmed |
| A3 | EP (16 experts / 16-way model axis) trades the f-contraction psum for an a2a of ≈ equal bytes | rules=ep | 58.22 → 36.33 s | refuted as a further win |
| A4–A6 | accum trades activation memory vs nothing on traffic/token | accum ∈ {2,4,8} | 19.90 s/36.2 GiB, 22.53 s/21.9 GiB, 27.79 s/14.8 GiB | fitting frontier = accum 8 |
| A7/A8 | cp would also remove the remaining seq collectives | cp + shard_map | 92.6 s, AG 339 GB/chip | **refuted decisively** — per-sequence dispatch needs full sequences under cp |

**Adopted (now the MoE-train default): A5** = shard_map + no-SP + accum 8:
**58.22 → 27.79 s, MFU bound 0.078 → 0.164 (2.09×), fits 14.8 GiB.**
Dominant term still collective (dispatch resharding + grad reduction);
next lever: sort-based dispatch to remove the scatter resharding.

### Cell B — glm4-9b × prefill_32k (baseline 1.847 s, MFU bound 0.212)

| iter | hypothesis | change | step before → after | verdict |
|---|---|---|---|---|
| B1 | Megatron-SP pays 4 residual-sized collectives/layer (≈2.1 GB); context parallelism gathers only GQA K/V (33 MB/layer) → collective 1.85 → ~0.4 s | cp preset | coll 1.85→0.86 ✓ but compute 0.80→4.99 s | partially confirmed |
| B2/B3 | q-chunk size is the compute regression | q_chunk 512/1024 | 4.99 → 4.99 s | refuted |
| — | *debug forward, per the methodology*: the HLO walker's per-loop breakdown pins the regression on MLP dots running 65,536 rows/chip — `mlp()`'s internal constraint forced a full-seq gather under cp; fixed the constraint (+ duplicate-axis protection in `spec()`) | | | bug found & fixed |
| B4 | re-measure the original hypothesis | cp (fixed) | 1.847 → 1.285 s, MFU 0.304 | **confirmed** (1.44×) |
| B6 | rest weights over all 256 chips (ZeRO fsdp_axes) | cp + zero | 1.285 → 1.276 s, peak 5.9→3.9 GiB | confirmed (memory) |
| B2/B3/B5 | — | — | three consecutive <5% | stop |

**Adopted (now the LM-prefill default): cp** — **1.847 → 1.276 s, MFU
bound 0.212 → 0.306 (1.45×).**  Remaining dominant term: FSDP weight
gathers, overlappable with compute on real hardware (latency-hiding
scheduler), so the achievable MFU is higher than the bound ratio suggests.

### Cell C — hymba-1.5b × prefill_32k (baseline 9.053 s, MFU bound 0.007)

| iter | hypothesis | change | step before → after | verdict |
|---|---|---|---|---|
| C1/C2/C4 | the SSD pairwise decay matrix [B,NC,c,c,H] (∝ chunk) dominates HBM traffic | ssm chunk 128→{16,32,64} | 9.05 → 9.05 s | **refuted** — not the hog |
| C3 | revised: the hog is full-seq activation gathers around the hybrid block under SP ([B,32768,1600]/layer); cp keeps tokens sharded | chunk32 + cp | 9.053 → 0.738 s | **confirmed** (12.3×) |
| C6/C8 | bigger chunks amortize the inter-chunk scan; ZeRO rest-sharding | cp, chunk 128 + zero | 0.738 → 0.611 s, 2.6 GiB | confirmed |

**Adopted: cp, chunk 128 — 9.053 → 0.611 s, MFU bound 0.007 → 0.108
(14.8×).**  Now memory-bound on the SSD einsums themselves — the next
lever is the Pallas SSD kernel (implemented in `kernels/ssd_scan.py`,
holds the decay matrix in VMEM; excluded from the dry-run path because
cost_analysis cannot see inside custom calls).  cp numerics for the
SSM/hybrid families validated to 5e-7 against single-device forward.

### Bonus cells promoted to defaults by the same loop

* **D (multi-pod) glm4-9b × train_4k × 2×16×16**: FSDP is structurally
  broken at batch 256 < 512 chips (model axis idle, 16× redundant compute:
  25.2 s, 37.4 GiB ✗).  cp shards sequence on the idle axis → **5.44 s,
  10.5 GiB ✓** after ZeRO rest-sharding.  Adopted for multi-pod train.
* **E dbrx-132b × prefill_32k**: 2D layout 15.48 s and 21.0 GiB ✗; cp +
  shard_map-MoE → **8.08 s, 13.8 GiB ✓** (1.92×).  Adopted for MoE prefill.
* **F gradient reduce-scatter pinning** (`grad_shardings` in
  make_train_step): hypothesis — the per-layer FSDP gradient reduction is
  emitted as a full all-reduce (1.32 GiB × 40 layers on dbrx) where a
  reduce-scatter would halve it.  Measured: no change on this container —
  the XLA:CPU SPMD pipeline lacks the ReduceScatterCreator pass that fires
  on the TPU toolchain.  Kept in the code (it is the correct production
  constraint); recorded as unmeasurable-here rather than refuted.
* **KV int8 quantization** (`LM(kv_quant=True)`): codeqwen decode_32k's
  bf16 MHA cache is 8.6 GiB/chip — over budget with the conservative
  estimate.  int8 + per-position scales (argmax-identical over 8 decode
  steps, logit Δ ≤ 6e-3): **18.7 → 6.8 GiB ✓**.  Adopted for MHA decode.

### Paper-faithful vs beyond-paper

The paper's technique (the MEP kernel loop) is reported separately below
and in bench_output.txt — that reproduction was completed and validated
first (§Paper-claims).  Everything in this section is beyond-paper
system-level optimization of the host framework, permitted by the brief
("even with approaches the paper didn't use"); both baselines and optimized
variants are recorded per cell above.
"""

KNOWN_ISSUES = """
## Known issues / residual caveats

* `codeqwen1.5-7b × decode_32k` initially exceeded the conservative TPU
  estimate (18.7 GiB: an 8.6 GiB bf16 MHA cache plus the f32 copy XLA:CPU
  materializes); fixed by int8 KV-cache quantization (6.8 GiB ✓, §Perf KV).
* `chameleon-34b`/`command-r-35b` train cells sit within ~10–15% of the
  16 GiB line under the conservative estimate; accum is the dial.
* Whisper's enc-dec is excluded from the cp preset (its decoder-side
  cross-attention layout was not reworked); its cells fit comfortably
  under the default rules.
* The embedding gather triggers XLA SPMD "involuntary full
  rematerialization" warnings on some decode cells (known XLA issue
  b/433785288); traffic is counted in the roofline.
* rwkv6 `useful_flops_ratio` slightly exceeds 1.0 on inference cells: the
  6·N·D yardstick over-counts its decay-LoRA parameters relative to the
  walker's elementwise accounting of the WKV outer products (<7% effect).
"""


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.jsonl"
    rows = load(path)
    n_ok = sum(r["status"] == "OK" for r in rows.values())
    n_skip = sum(r["status"] == "SKIP" for r in rows.values())
    n_fail = sum(r["status"] == "FAIL" for r in rows.values())
    fits = sum(r["status"] == "OK" and r["memory"]["fits_hbm"]
               for r in rows.values())
    hdr = (f"Sweep result: **{n_ok} OK / {n_skip} SKIP / {n_fail} FAIL** "
           f"({fits}/{n_ok} within the 16 GiB HBM estimate); 40 assigned "
           f"cells × 2 meshes = 80, with the 8 documented long_500k skips "
           f"per mesh.\n\n")
    md = open("benchmarks/EXPERIMENTS.template.md").read()
    md = md.replace("<!-- DRYRUN_TABLE -->", hdr + dryrun_table(rows))
    md = md.replace("<!-- ROOFLINE_TABLE -->", roofline_table(rows))
    md = md.replace("<!-- PERF_LOG -->", PERF_NARRATIVE)
    md = md.replace("<!-- KNOWN_ISSUES -->", KNOWN_ISSUES)
    open("EXPERIMENTS.md", "w").write(md)
    print(f"assembled: {n_ok} ok / {n_skip} skip / {n_fail} fail, "
          f"{fits} fit")


if __name__ == "__main__":
    main()
