"""Table 11 — Population search: multi-expert personae, tournament
racing, island migration (ROADMAP "Population search"; not a paper
table).

The paper's §3.2 loop advances one lineage per kernel.  This table
measures what the ``core.population`` engine buys on top of the
strongest greedy configuration (table 10's ``diagnose=True`` leg,
replicated here verbatim as the baseline): a per-case population whose
generations fan out to four expert personae, race every challenger
against a tournament-sampled opponent, and exchange winning deltas
between concurrent cases through the shared PatternStore journal.

Four legs:

* **greedy**     — ``HeuristicProposer(diagnose=True)``, the table 10
  baseline: one variant lineage, no pattern store.
* **population** — the same cases under ``PopulationConfig``: expert
  waves + tournament racing + island migration over a width-1 fabric
  (sequential cases, so migration order is deterministic).
* **population-subprocess** — the population leg through the worker
  fabric with a journaled PatternStore and ResultsDB; the journal must
  carry persona provenance, raced-kill counts, and migration events on
  every generation record (the wire-path acceptance gate).
* **racing**     — a measured (CPU wall-clock) slice: tournament
  racing must actually retire challengers (``raced_kills > 0``) —
  the analytic platform never races, so this is the only leg that can
  demonstrate the kill mechanism end-to-end.

The headline metric is **paid evals to best-known**: walking each
leg's candidates in evaluation order, how many cache-miss evaluations
it spends before first hitting the best quality EITHER leg ever
reaches on that case (censored at the leg's total spend when it never
gets there).  The acceptance gate: on >= 4 kernel families the
population leg reaches equal-or-better winners with >= 1.3x fewer
paid evals to best.

    PYTHONPATH=src python -m benchmarks.run --tables 11
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict, Optional

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, CPUPlatform, EvalCache,
                        HeuristicProposer, InProcessExecutor,
                        MeasureConfig, MEPConstraints, OptConfig,
                        PatternStore, PopulationConfig, ResultsDB,
                        SubprocessExecutor, TPUModelPlatform, get_case)

# multi-case families are where island migration pays: the first case
# of a family pays the expert-wave exploration, its siblings inherit
# the winning delta as a generation-0 seed.  Order matters on the
# width-1 fabric — each family leads with its best teacher (the case
# whose winning delta transfers whole to its siblings; gemver's
# optimum is a superset of the other matvec winners, so seeding it
# FROM a sibling's partial delta would cost an extra generation).
# attention_prefill runs first: single-case, so its only edge is the
# bottleneck-routed expert nailing eval 1 before any store seeds
# exist.  scan / sort ride along as controls where greedy's routed
# recipe is already near-optimal (tiny 2-key spaces → the expert wave
# can only tie or pay overhead).
CASES = ["attention_prefill",                         # attention
         "gemver", "atax", "bicg", "gesummv",         # matvec
         "gemm", "2mm", "3mm", "syrk", "syr2k",       # matmul
         "adi", "dwthaar1d", "simpleconvolution",     # stencil
         "binomialoption", "rwkv_wkv", "mamba_ssd",   # scan
         "bitonicsort"]                               # sort
CFG = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1)
POP = PopulationConfig(size=4, generations=6, per_persona=1)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0
TIE = 1e-9          # equal-quality epsilon on time comparisons


def _evals_to_target(res, target_s: float) -> Dict:
    """Paid (cache-miss) evaluations spent, in evaluation order, before
    the first full-fidelity candidate at or below ``target_s``
    (unrounded seconds); censored at the leg's total paid spend when
    never reached."""
    paid = 0
    for rl in res.rounds:
        for c in rl.candidates:
            if not c.cached:
                paid += 1
            if c.status == "ok" and not c.raced_out \
                    and c.time_s <= target_s * (1 + TIE):
                return {"evals": paid, "reached": True}
    total = sum(1 for rl in res.rounds for c in rl.candidates
                if not c.cached)
    return {"evals": total, "reached": False}


def _leg(tag: str, *, executor, tmp: str, population=None,
         store=None, db=None) -> Dict:
    jobs = [CaseJob(get_case(n),
                    HeuristicProposer(SEED, platform="tpu-model",
                                      diagnose=True),
                    cfg=CFG, constraints=CONS, seed=SEED) for n in CASES]
    camp = Campaign(TPUModelPlatform(), patterns=store, db=db,
                    cache=EvalCache(os.path.join(tmp, f"ec_{tag}.jsonl")),
                    executor=executor, population=population)
    t0 = time.time()
    results = camp.run(jobs)
    wall = time.time() - t0
    per_case = {}
    for res in results:
        per_case[res.case_name] = {
            "family": get_case(res.case_name).family,
            "rounds": len(res.rounds),
            "evals": res.cache_misses,
            "best_us": round(res.best_time_s * 1e6, 3),
            "speedup": round(res.speedup, 4),
            "raced_kills": res.raced_kills,
            "migrations_in": res.migrations_in,
            "migrations_joined": res.migrations_joined,
            "migrations_out": res.migrations_out,
            "persona_stats": res.persona_stats,
            "_res": res,           # stripped before serialization
        }
    leg = {
        "population": population is not None,
        "wall_s": round(wall, 2),
        "total_evals": sum(c["evals"] for c in per_case.values()),
        "cases": per_case,
    }
    print(f"#   {tag}: {leg['total_evals']} paid evals, "
          f"{sum(c['raced_kills'] for c in per_case.values())} raced "
          f"kills, "
          f"{sum(c['migrations_joined'] for c in per_case.values())} "
          f"migrants joined, {wall:.1f}s wall", flush=True)
    return leg


def _racing_leg(tmp: str) -> Dict:
    """Measured slice: CPU wall clock, tight CI budget — the tournament
    must retire challengers at r_min (raced_kills > 0)."""
    pcfg = PopulationConfig(size=3, generations=3, per_persona=2,
                            migrate=False)
    # r=30 gives racing headroom above r_min; the tight ci_rel keeps
    # the timer measuring until the race decision fires (otherwise
    # losers stop early as cheap full-fidelity records instead)
    cfg = OptConfig(d_rounds=8, n_candidates=2, r=30, k=3,
                    measure=MeasureConfig(ci_rel=0.001))
    jobs = [CaseJob(get_case(n),
                    HeuristicProposer(SEED, platform="cpu"),
                    cfg=cfg, constraints=MEPConstraints(r=30, k=3,
                                                        t_max_s=2.0),
                    seed=SEED)
            for n in ("atax", "bicg")]
    camp = Campaign(CPUPlatform(),
                    cache=EvalCache(os.path.join(tmp, "ec_race.jsonl")),
                    executor=InProcessExecutor(1), population=pcfg)
    t0 = time.time()
    results = camp.run(jobs)
    leg = {
        "platform": "cpu",
        "wall_s": round(time.time() - t0, 2),
        "cases": {r.case_name: {
            "raced_kills": r.raced_kills,
            "evals": r.cache_misses,
            "timing_reps": r.timing_reps,
            "timing_reps_fixed": r.timing_reps_fixed,
            "speedup": round(r.speedup, 3),
        } for r in results},
        "raced_kills": sum(r.raced_kills for r in results),
    }
    print(f"#   racing (cpu): {leg['raced_kills']} tournament kills, "
          f"{sum(r.timing_reps for r in results)} reps paid vs "
          f"{sum(r.timing_reps_fixed for r in results)} fixed-R, "
          f"{leg['wall_s']}s wall", flush=True)
    return leg


def _journal_evidence(db_path: str) -> Dict:
    """Wire-path acceptance gate: generation records written by the
    *subprocess* workers must carry persona provenance, raced-kill
    counts, and migration events."""
    rounds = list(ResultsDB(db_path).records("round"))
    with_personae = [r for r in rounds if r.get("personae")]
    migrations = [m for r in rounds for m in r.get("migrations", [])]
    return {
        "round_records": len(rounds),
        "rounds_with_personae": len(with_personae),
        "rounds_with_raced_kills_field": sum(
            1 for r in rounds if "raced_kills" in r),
        "personae_seen": sorted({p for r in with_personae
                                 for p in r["personae"]}),
        "migration_events": len(migrations),
        "migrations_joined": sum(1 for m in migrations if m.get("joined")),
        "candidates_with_persona": sum(
            1 for r in rounds for c in r.get("candidates", [])
            if c.get("persona")),
    }


def main(ctx=None) -> Dict:
    bench = ensure_ctx(ctx)      # table 11 owns its stores: legs must
    pop_cfg = bench.population or POP       # not share with other tables
    tmp = tempfile.mkdtemp(prefix="pop_demo_")
    print(f"# population demo: cases={CASES}, pop size={pop_cfg.size}, "
          f"generations={pop_cfg.generations}, "
          f"per_persona={pop_cfg.per_persona}", flush=True)
    try:
        greedy = _leg("greedy", executor=InProcessExecutor(1), tmp=tmp)
        pop = _leg("population", executor=InProcessExecutor(1), tmp=tmp,
                   population=pop_cfg,
                   store=PatternStore(os.path.join(tmp, "pat_pop.jsonl")))
        db_path = os.path.join(tmp, "db_sub.jsonl")
        sub = _leg("population-subprocess", executor=SubprocessExecutor(2),
                   tmp=tmp, population=pop_cfg,
                   store=PatternStore(os.path.join(tmp, "pat_sub.jsonl")),
                   db=ResultsDB(db_path))
        evidence = _journal_evidence(db_path)
        racing = _racing_leg(tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # -- paid-evals-to-best-known, per case then per family ------------
    per_family: Dict[str, Dict] = {}
    for n in CASES:
        g, p = greedy["cases"][n], pop["cases"][n]
        g_res, p_res = g.pop("_res"), p.pop("_res")
        # target from the UNROUNDED times: best quality either leg ever
        # reached on this case (the display best_us is 3-decimal µs,
        # which would censor sub-rounding-error matches)
        target = min(g_res.best_time_s, p_res.best_time_s)
        ge = _evals_to_target(g_res, target)
        pe = _evals_to_target(p_res, target)
        sub["cases"][n].pop("_res", None)
        g["evals_to_best"], g["reached_best"] = ge["evals"], ge["reached"]
        p["evals_to_best"], p["reached_best"] = pe["evals"], pe["reached"]
        fam = g["family"]
        f = per_family.setdefault(fam, {
            "cases": 0, "equal_or_better_winners": 0,
            "evals_to_best_greedy": 0, "evals_to_best_population": 0})
        f["cases"] += 1
        f["equal_or_better_winners"] += int(
            p_res.best_time_s <= g_res.best_time_s * (1 + TIE))
        f["evals_to_best_greedy"] += ge["evals"]
        f["evals_to_best_population"] += pe["evals"]
    for f in per_family.values():
        f["evals_ratio"] = round(
            f["evals_to_best_greedy"]
            / max(1, f["evals_to_best_population"]), 3)
    improved = sorted(
        fam for fam, f in per_family.items()
        if f["equal_or_better_winners"] == f["cases"]
        and f["evals_to_best_greedy"]
        >= 1.3 * f["evals_to_best_population"])

    rec = {
        "table": "table11_population",
        "cases": CASES,
        "cfg": {"d_rounds": CFG.d_rounds, "n_candidates": CFG.n_candidates,
                "r": CFG.r, "k": CFG.k},
        "population_cfg": pop_cfg.to_dict(),
        "legs": {"greedy": greedy, "population": pop,
                 "population_subprocess": sub, "racing": racing},
        "per_family": per_family,
        "families_improved": improved,
        "evals_to_best_greedy": sum(
            f["evals_to_best_greedy"] for f in per_family.values()),
        "evals_to_best_population": sum(
            f["evals_to_best_population"] for f in per_family.values()),
        "journal_evidence": evidence,
    }
    rec["evals_to_best_ratio"] = round(
        rec["evals_to_best_greedy"]
        / max(1, rec["evals_to_best_population"]), 3)
    print(f"# table11_population: evals-to-best "
          f"{rec['evals_to_best_greedy']} (greedy) -> "
          f"{rec['evals_to_best_population']} (population), "
          f"{rec['evals_to_best_ratio']}x; families with equal-or-better "
          f"winners at >=1.3x fewer evals: {improved} "
          f"({len(improved)}/{len(per_family)}); racing leg kills: "
          f"{racing['raced_kills']}; journal: "
          f"{evidence['rounds_with_personae']}/"
          f"{evidence['round_records']} generations with persona stats, "
          f"{evidence['migration_events']} migration events", flush=True)
    out = os.path.join("results", "table11_population.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
