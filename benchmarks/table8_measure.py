"""Table 8 — adaptive measurement economics (eq. 3 beyond fixed R).

The paper's evaluation cost is dominated by eq. 3's fixed budget: R=30
repeated runs per candidate, losers included.  The adaptive measurement
engine (``repro.core.measure``) keeps eq. 3's semantics — the cap is
the paper's R, k-trimming applied to whatever was collected — while
spending only the reps a timing needs (CI-based early stop) and
aborting provably-losing candidates (incumbent racing).  Four legs over
one multi-kernel CPU campaign (5 kernels, candidate pools drawn from
the real variant spaces with clear winner separations):

* **fixed**    — the campaign under fixed R=30, with every timing's
  full rep stream recorded.
* **replay**   — the controlled winner-identity comparison: the
  adaptive engine re-fed the *fixed leg's recorded rep streams* (a
  prefix of the exact same measurements), so ≥2x rep reduction and
  winner equality are judged on identical data — the bench-scale
  version of the hypothesis property
  ``test_adaptive_stopping_preserves_fixed_r_winner``.
* **adaptive** — the same campaign live under the adaptive engine
  (CI stop + racing): the end-to-end rep and wall-clock economy of a
  real run.  (Live winners are additionally reported; the pools keep
  every non-winner ≥75% from its winner so they match across legs
  despite the minute-scale load drift of a shared host.)
* **fanout**   — a measured-platform campaign on ``SubprocessExecutor``
  with 2 workers: pinning deleted, wall-clock slices serialized on the
  cross-process timing lease, per-candidate CI half-widths audited
  against the configured threshold (eq. 3 cap respected).

    PYTHONPATH=src python -m benchmarks.run --tables 8
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import time
from collections import defaultdict
from typing import Dict, List

if __name__ == "__main__":      # standalone: make repo imports resolvable
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_here, ".."))
    sys.path.insert(0, os.path.join(_here, "..", "src"))

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, CPUPlatform, EvalCache,
                        HeuristicProposer, InProcessExecutor, MeasureConfig,
                        OptConfig, MEPConstraints, Proposer,
                        SubprocessExecutor, get_case, measure_callable)

R_CAP, K = 30, 3                           # the paper's eq. 3 parameters
CI_REL = 0.10                              # adaptive stop threshold (legs 1-3)
FANOUT_CI_REL = 0.25                       # threshold audited in the fan-out leg
SEED = 0
CONS = MEPConstraints(r=R_CAP, k=K, t_max_s=0.8)

# Candidate pools from the real variant spaces, chosen so every
# non-winning candidate sits far (≥75%) from its case's winner: the
# winner-identity claim must survive not just within-run timing noise
# but the minute-scale machine drift between the fixed and adaptive
# legs (shared-host CPU, 2 cores).  Candidates whose margin to the
# winner is drift-sized (fused one-pass atax, separable-vs-shifts
# conv) are deliberately excluded — no eq. 3 budget can rank those
# reliably across runs on this hardware.
POOLS: Dict[str, List[Dict]] = {
    # bf16 matvec losers (~1.8–3x slower): racing retires each early
    "atax": [{"compute_dtype": "bf16", "block": 256},
             {"compute_dtype": "bf16", "block": 128}],
    # bf16 losers (~4.5x slower)
    "gesummv": [{"compute_dtype": "bf16", "block": 256},
                {"compute_dtype": "bf16", "block": 128}],
    # a ~7x loser: races out almost immediately
    "dwthaar1d": [{"one_pass": True}],
    # fused one-pass wins ~2x; both two-pass variants are ~2x behind it
    "vectoradd": [{"one_pass": True, "block": 16384},
                  {"one_pass": False, "block": 16384},
                  {"one_pass": False, "block": 8192}],
    # shift-based conv wins ~20x over the xla_conv baseline
    "simpleconvolution": [{"method": "shifts"}],
}


class PoolProposer(Proposer):
    """Round-0 scripted proposer: the case's fixed candidate pool, then
    nothing (one-round campaign) — keeps every leg's candidate set
    identical by construction."""
    name = "pool"

    def propose(self, case, state, n):
        return [dict(case.baseline_variant, **d)
                for d in POOLS[case.name]] if state.round == 0 else []


class RecordingCPU(CPUPlatform):
    """CPU platform that journals every timing's full rep stream, keyed
    by (case, variant), FIFO per key — the replay leg re-feeds them to
    the adaptive engine."""

    def __init__(self):
        super().__init__()
        self.streams: Dict[tuple, List[List[float]]] = defaultdict(list)

    def time_variant(self, case, variant, scale, inputs, *, r, k,
                     budget=None, incumbent_s=None):
        res = super().time_variant(case, variant, scale, inputs, r=r, k=k,
                                   budget=budget, incumbent_s=incumbent_s)
        if len(res.times_s) >= R_CAP:     # skip MEP auto-sizing probes
            key = (case.name, tuple(sorted(variant.items())))
            self.streams[key].append(list(res.times_s))
        return res


def _jobs(cfg: OptConfig) -> List[CaseJob]:
    return [CaseJob(get_case(n), PoolProposer(), cfg=cfg,
                    constraints=CONS, seed=SEED) for n in POOLS]


def _leg(tag: str, platform, measure: MeasureConfig, tmp: str):
    """One serial CPU campaign under the given measurement policy; no
    eval cache, so every timing is actually paid (honest rep counts)."""
    cfg = OptConfig(d_rounds=1, n_candidates=8, r=R_CAP, k=K)
    camp = Campaign(platform, executor=InProcessExecutor(1),
                    measure=measure,
                    lease_path=os.path.join(tmp, f"lease_{tag}.lock"))
    t0 = time.time()
    results = camp.run(_jobs(cfg))
    wall = time.time() - t0
    leg = {
        "wall_s": round(wall, 2),
        "total_reps": sum(r.timing_reps for r in results),
        "total_reps_fixed_equiv": sum(r.timing_reps_fixed for r in results),
        "raced_out": sum(r.raced_out for r in results),
        "winners": {r.case_name: r.best_variant for r in results},
        "speedups": {r.case_name: round(r.speedup, 4) for r in results},
    }
    print(f"#   {tag}: {leg['total_reps']} reps paid "
          f"(fixed-R equivalent {leg['total_reps_fixed_equiv']}), "
          f"{leg['raced_out']} raced out, {wall:.1f}s wall", flush=True)
    return leg, results


def _replay(recorder: RecordingCPU, fixed_results) -> Dict:
    """Same-stream comparison: run the adaptive engine over the fixed
    leg's recorded rep streams, mirroring the round-0 search semantics
    (baseline = incumbent, racing, raced-out excluded from the argmin).
    Winner equality here is judged on *identical measurements*."""
    streams = {k: list(v) for k, v in recorder.streams.items()}

    def pop(case_name, variant):
        return streams[(case_name, tuple(sorted(variant.items())))].pop(0)

    total = raced = 0
    winners = {}
    for res in fixed_results:
        case = get_case(res.case_name)
        base_stream = pop(res.case_name, res.baseline_variant)
        base = measure_callable(iter(base_stream).__next__, r=R_CAP, k=K,
                                cfg=MeasureConfig(ci_rel=CI_REL))
        total += base.r
        incumbent = base.trimmed_mean_s
        best_v, best_t = dict(res.baseline_variant), incumbent
        for rl in res.rounds:
            for c in rl.candidates:
                if c.status != "ok":
                    continue
                r = measure_callable(
                    iter(pop(res.case_name, c.variant)).__next__,
                    r=R_CAP, k=K, cfg=MeasureConfig(ci_rel=CI_REL),
                    incumbent_s=incumbent)
                total += r.r
                if r.raced_out:
                    raced += 1
                elif r.trimmed_mean_s < best_t:
                    best_v, best_t = dict(c.variant), r.trimmed_mean_s
        winners[res.case_name] = best_v
    return {"total_reps": total, "raced_out": raced, "winners": winners}


def _ci_audit(results, threshold: float) -> Dict:
    """Per-candidate audit of the fan-out leg: every completed timing's
    CI half-width meets the threshold, unless it ran to the eq. 3 cap
    (noise floor) or was raced out (loss by construction)."""
    ok = met = capped = raced = 0
    for res in results:
        for rl in res.rounds:
            for c in rl.candidates:
                if c.status != "ok":
                    continue
                ok += 1
                if c.raced_out:
                    raced += 1
                elif c.ci_half_width_s <= threshold * c.time_s:
                    met += 1
                elif c.reps >= R_CAP:
                    capped += 1
    return {"timed_candidates": ok, "ci_met": met, "hit_r_cap": capped,
            "raced_out": raced,
            "all_accounted": ok == met + capped + raced}


def main(ctx=None) -> Dict:
    ensure_ctx(ctx)      # table 8 owns its campaigns: legs must not share
    tmp = tempfile.mkdtemp(prefix="measure_demo_")
    print(f"# measurement demo: cases={list(POOLS)}, R={R_CAP}, k={K}, "
          f"ci_rel={CI_REL}", flush=True)
    try:
        recorder = RecordingCPU()
        fixed, fixed_results = _leg(
            "fixed-R", recorder, MeasureConfig(adaptive=False, race=False),
            tmp)
        adaptive, _ = _leg("adaptive", CPUPlatform(),
                           MeasureConfig(ci_rel=CI_REL), tmp)
        replay = _replay(recorder, fixed_results)
        print(f"#   replay: {replay['total_reps']} reps on the fixed "
              f"leg's streams, {replay['raced_out']} raced out", flush=True)

        # fan-out: measured platform over 2 subprocess workers, pinning
        # deleted — the flock lease next to the shared cache serializes
        # wall-clock slices across the worker processes
        ex = SubprocessExecutor(2)
        cache = EvalCache(os.path.join(tmp, "ec_fanout.jsonl"))
        camp = Campaign(CPUPlatform(), executor=ex, cache=cache,
                        measure=MeasureConfig(ci_rel=FANOUT_CI_REL))
        fan_cfg = OptConfig(d_rounds=1, n_candidates=3, r=R_CAP, k=K)
        fan_jobs = [CaseJob(get_case(n), HeuristicProposer(SEED),
                            cfg=fan_cfg, constraints=CONS, seed=SEED)
                    for n in ("atax", "bicg", "gesummv")]
        t0 = time.time()
        try:
            fan_results = camp.run(fan_jobs)
        finally:
            slots = {s for _, s in ex.dispatch_log}
            ex.close()
        fanout = {
            "wall_s": round(time.time() - t0, 2),
            "executor": "subprocess",
            "workers": 2,
            "worker_slots_used": sorted(str(s) for s in slots),
            "lease_file": os.path.basename(camp.lease_path),
            "ci_rel": FANOUT_CI_REL,
            "total_reps": sum(r.timing_reps for r in fan_results),
            "total_reps_fixed_equiv": sum(r.timing_reps_fixed
                                          for r in fan_results),
            "winners": {r.case_name: r.best_variant for r in fan_results},
            "ci_audit": _ci_audit(fan_results, FANOUT_CI_REL),
        }
        print(f"#   fanout: slots {fanout['worker_slots_used']}, "
              f"{fanout['total_reps']} reps, ci audit "
              f"{fanout['ci_audit']}", flush=True)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    savings_live = fixed["total_reps"] / max(1, adaptive["total_reps"])
    savings_replay = fixed["total_reps"] / max(1, replay["total_reps"])
    rec = {
        "table": "table8_measure",
        "cases": list(POOLS),
        "pools": POOLS,
        "cfg": {"r": R_CAP, "k": K, "ci_rel": CI_REL,
                "fanout_ci_rel": FANOUT_CI_REL},
        "legs": {"fixed": fixed, "adaptive": adaptive, "replay": replay,
                 "fanout": fanout},
        "rep_savings_live_x": round(savings_live, 2),
        "rep_savings_same_stream_x": round(savings_replay, 2),
        "winners_match_live": fixed["winners"] == adaptive["winners"],
        "winners_match_same_stream": fixed["winners"] == replay["winners"],
        "fanout_multiprocess_ok":
            len(fanout["worker_slots_used"]) >= 2
            and fanout["ci_audit"]["all_accounted"],
    }
    print(f"# table8_measure: {fixed['total_reps']} -> "
          f"{adaptive['total_reps']} reps live ({savings_live:.2f}x), "
          f"-> {replay['total_reps']} on identical streams "
          f"({savings_replay:.2f}x); winners match live="
          f"{rec['winners_match_live']} same-stream="
          f"{rec['winners_match_same_stream']}; measured fan-out over "
          f"{len(fanout['worker_slots_used'])} workers", flush=True)
    out = os.path.join("results", "table8_measure.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    main()
