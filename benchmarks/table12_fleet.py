"""Table 12 — the networked campaign fleet demonstration (not a paper
table).

One campaign across N simulated hosts, three claims, all on analytic
(TPU-model) cases so every number is deterministic:

1. **Equivalence** — a 2-"host" fleet campaign (``RemoteExecutor`` over
   loopback sockets, each worker server under its own
   ``REPRO_HOST_ALIAS``) produces winner records identical to the
   single-host ``SubprocessExecutor`` run: the spec wire form, the
   per-host lease/namespace resolution, and the affinity router change
   *where* evaluations run, never *what* they decide.
2. **Provenance & isolation** — every ``case_result`` / ``round``
   record journals the fleet host that produced it, and the shared
   eval-cache file ends up holding records namespaced per host — the
   measured-replay firewall (host A's wall-clock timings never replay
   on host B) demonstrated at the namespace level.
3. **Replication** — hosts that do NOT share the scheduler's filesystem
   (per-host ``cache_path`` / ``db_path`` remaps) converge through the
   ``repro.core.replicate`` tail-ship loop: winners still identical,
   and every host journal line is home in the scheduler's journals by
   campaign end.

Output JSON (aggregate ``--out`` and, standalone,
``results/table12_fleet.json``) carries the three verdicts, the
per-host work split, and the wall-clocks.

    PYTHONPATH=src python -m benchmarks.run --tables 12
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, List

from benchmarks.common import ensure_ctx
from benchmarks.table6_workers import WINNER_FIELDS, winner_records
from repro.core import (Campaign, CaseJob, EvalCache, FleetHost,
                        HeuristicProposer, MEPConstraints, OptConfig,
                        RemoteExecutor, ResultsDB, SubprocessExecutor,
                        TPUModelPlatform, get_case)

CASES = ["2mm", "3mm", "atax", "bicg", "gemm", "gemver", "gesummv",
         "syr2k"]
CFG = OptConfig(d_rounds=4, n_candidates=3, r=5, k=1)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0
FLEET = ("fleetA", "fleetB")


def _jobs() -> List[CaseJob]:
    # fresh seeded proposers per leg: equivalence rests on every leg
    # seeing the identical RNG stream (and no shared PatternStore)
    return [CaseJob(get_case(name), HeuristicProposer(SEED),
                    cfg=CFG, constraints=CONS, seed=SEED)
            for name in CASES]


def _hosts_seen(db: ResultsDB) -> Dict[str, int]:
    seen: Dict[str, int] = {}
    for r in db.records("case_result"):
        h = r.get("host") or "?"
        seen[h] = seen.get(h, 0) + 1
    return seen


def _cache_namespaces(cache_path: str) -> List[str]:
    out = set()
    try:
        with open(cache_path) as f:
            for ln in f:
                if ln.strip():
                    out.add(json.loads(ln).get("ns", ""))
    except OSError:
        pass
    return sorted(out)


def _run(tag: str, executor, cache_path: str, db_path: str) -> Dict:
    cache = EvalCache(cache_path)
    db = ResultsDB(db_path)
    camp = Campaign(TPUModelPlatform(), cache=cache, db=db,
                    executor=executor)
    executor.warm()       # spawn + jax import outside the timed region
    t0 = time.time()
    results = camp.run(_jobs())
    wall = time.time() - t0
    print(f"#   {tag}: {wall:.1f}s wall, "
          f"{sum(len(r.rounds) for r in results)} rounds total", flush=True)
    try:
        return {"wall_s": round(wall, 2), "db": db, "cache": cache,
                "speedups": {r.case_name: round(r.speedup, 4)
                             for r in results}}
    finally:
        executor.close()


def main(ctx=None) -> Dict:
    ctx = ensure_ctx(ctx)
    tmp = tempfile.mkdtemp(prefix="fleet_demo_")
    print(f"# fleet demo: {len(CASES)} analytic cases across "
          f"{len(FLEET)} simulated hosts (loopback spawn transport)",
          flush=True)

    # leg A: the single-host reference — SubprocessExecutor, cold cache
    ref = _run("single-host subprocess workers=2", SubprocessExecutor(2),
               os.path.join(tmp, "cache_a.jsonl"),
               os.path.join(tmp, "db_a.jsonl"))
    # leg B: the fleet on a shared filesystem — 2 loopback worker
    # servers, each its own host identity, one shared cache/db file
    fleet = _run(
        "fleet 2 hosts (shared filesystem)",
        RemoteExecutor([{"name": h} for h in FLEET]),
        os.path.join(tmp, "cache_b.jsonl"),
        os.path.join(tmp, "db_b.jsonl"))
    # leg C: the fleet WITHOUT a shared filesystem — per-host journal
    # remaps, converged by the tail-ship replication loop
    rep_hosts = [FleetHost(name=f"rep{h[-1].upper()}",
                           cache_path=os.path.join(tmp, f"{h}_cache.jsonl"),
                           db_path=os.path.join(tmp, f"{h}_db.jsonl"))
                 for h in FLEET]
    repl = _run("fleet 2 hosts (replicated journals)",
                RemoteExecutor(rep_hosts),
                os.path.join(tmp, "cache_c.jsonl"),
                os.path.join(tmp, "db_c.jsonl"))

    ref_w = winner_records(ref["db"])
    identical_fleet = winner_records(fleet["db"]) == ref_w
    identical_repl = winner_records(repl["db"]) == ref_w
    fleet_hosts = _hosts_seen(fleet["db"])
    repl_hosts = _hosts_seen(repl["db"])
    fleet_ns = _cache_namespaces(os.path.join(tmp, "cache_b.jsonl"))
    per_host_ns = all(any(h in ns for ns in fleet_ns) for h in FLEET)
    # replication verdict: every host journal's cache keys made it home
    sched_keys = {json.loads(ln)["key"]
                  for ln in open(os.path.join(tmp, "cache_c.jsonl"))
                  if ln.strip()}
    shipped_home = all(
        {json.loads(ln)["key"] for ln in open(h.cache_path)
         if ln.strip()} <= sched_keys
        for h in rep_hosts)

    rec = {
        "table": "table12_fleet",
        "cases": CASES,
        "fleet": list(FLEET),
        "winner_fields": list(WINNER_FIELDS),
        "wall_s_single_host": ref["wall_s"],
        "wall_s_fleet_shared_fs": fleet["wall_s"],
        "wall_s_fleet_replicated": repl["wall_s"],
        "winners_identical_fleet": identical_fleet,
        "winners_identical_replicated": identical_repl,
        "hosts_seen_fleet": fleet_hosts,
        "hosts_seen_replicated": repl_hosts,
        "all_hosts_worked": sorted(fleet_hosts) == sorted(FLEET),
        "cache_namespaces_fleet": fleet_ns,
        "per_host_namespaces": per_host_ns,
        "replication_shipped_home": shipped_home,
        "case_speedups": ref["speedups"],
    }
    print(f"# table12_fleet: winners identical: fleet={identical_fleet} "
          f"replicated={identical_repl}; hosts {fleet_hosts}; "
          f"per-host namespaces={per_host_ns}; "
          f"replication home={shipped_home}", flush=True)
    for leg in (ref, fleet, repl):
        leg.pop("db", None)
        leg.pop("cache", None)
    rec["legs"] = {"single_host": ref, "fleet_shared_fs": fleet,
                   "fleet_replicated": repl}
    out = os.path.join("results", "table12_fleet.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
