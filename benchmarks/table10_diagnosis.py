"""Table 10 — Diagnosis-driven proposals: search-cost reduction
(paper §3.1 profile feedback, operationalized; not a paper table).

The paper feeds raw profiler counters into the proposer prompt; this
table measures what the structured ``core.diagnosis`` layer adds on
top.  Per (case, round) the search loop classifies the incumbent's
bottleneck (memory / compute / latency / collective / occupancy) from
the analytic Roofline terms and ``profile_feedback`` counters, and the
``HeuristicProposer`` routes its move set accordingly — the decisive
combined recipe for the diagnosed bottleneck first, instead of crawling
the legacy raw-threshold branches.  Three legs over a multi-family case
list:

* **diagnosed**    — ``HeuristicProposer(diagnose=True)`` (default).
* **undiagnosed**  — the same proposer with ``diagnose=False``: the
  legacy arithmetic-intensity / latency-fraction threshold branches,
  byte-for-byte the pre-diagnosis behavior.
* **diagnosed-subprocess** — the diagnosed leg through the worker
  fabric with a journaled ``PatternStore`` and ``ResultsDB``; checks
  that every round record carries the ``diagnosis`` verdict and the
  per-hint acceptance evidence end-to-end through the subprocess
  executor (the wire-safety acceptance gate).

The claim mirrors Table 7's economics: the diagnosed proposer must
reach the *identical* winner in fewer rounds-to-best (or fewer paid
evaluations) on at least three kernel families.

    PYTHONPATH=src python -m benchmarks.run --tables 10
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Dict

from benchmarks.common import ensure_ctx
from repro.core import (Campaign, CaseJob, EvalCache, HeuristicProposer,
                        InProcessExecutor, MEPConstraints, OptConfig,
                        PatternStore, ResultsDB, SubprocessExecutor,
                        TPUModelPlatform, get_case)

# two+ cases per family where the analytic model has a real optimum to
# find; families must span distinct bottleneck classes (memory-bound
# matmul/matvec, serialization-bound scan, mixed attention)
CASES = ["gemm", "2mm",                  # matmul
         "atax", "gemver", "bicg",      # matvec
         "binomialoption", "rwkv_wkv",  # scan
         "attention_prefill",           # attention
         "bitonicsort"]                 # sort
CFG = OptConfig(d_rounds=8, n_candidates=2, r=5, k=1)
CONS = MEPConstraints(r=5, k=1, t_max_s=2.0)
SEED = 0


def _rounds_to_best(res) -> int:
    """1-based index of the first round whose winner already matches the
    final best time (0 → the baseline was never beaten)."""
    for i, rl in enumerate(res.rounds):
        if rl.best_time_s <= res.best_time_s * (1 + 1e-12):
            return i + 1
    return 0


def _leg(tag: str, *, diagnose: bool, executor, tmp: str,
         store=None, db=None) -> Dict:
    jobs = [CaseJob(get_case(n),
                    HeuristicProposer(SEED, platform="tpu-model",
                                      diagnose=diagnose),
                    cfg=CFG, constraints=CONS, seed=SEED) for n in CASES]
    camp = Campaign(TPUModelPlatform(), patterns=store, db=db,
                    cache=EvalCache(os.path.join(tmp, f"ec_{tag}.jsonl")),
                    executor=executor)
    t0 = time.time()
    results = camp.run(jobs)
    wall = time.time() - t0
    per_case = {}
    for res in results:
        per_case[res.case_name] = {
            "family": get_case(res.case_name).family,
            "rounds": len(res.rounds),
            "rounds_to_best": _rounds_to_best(res),
            "evals": res.cache_misses,
            "best_us": round(res.best_time_s * 1e6, 3),
            "speedup": round(res.speedup, 4),
            "hints_suggested": res.hints_suggested,
            "hints_accepted": res.hints_accepted,
        }
    leg = {
        "diagnose": diagnose,
        "wall_s": round(wall, 2),
        "total_rounds_to_best": sum(
            c["rounds_to_best"] for c in per_case.values()),
        "total_evals": sum(c["evals"] for c in per_case.values()),
        "cases": per_case,
    }
    print(f"#   {tag}: {leg['total_rounds_to_best']} rounds-to-best, "
          f"{leg['total_evals']} paid evals, {wall:.1f}s wall", flush=True)
    return leg


def _journal_evidence(db_path: str) -> Dict:
    """The acceptance gate for the wire path: round records written by
    the *subprocess* worker must carry the diagnosis verdict and the
    per-hint acceptance evidence (delta / bottleneck / accepted /
    pid / ns provenance)."""
    rounds = list(ResultsDB(db_path).records("round"))
    with_diag = [r for r in rounds if r.get("diagnosis")]
    hints = [h for r in rounds for h in r.get("ppi_hints", [])]
    complete = [h for h in hints
                if {"delta", "bottleneck", "accepted", "pid",
                    "ns"} <= set(h)]
    return {
        "round_records": len(rounds),
        "rounds_with_diagnosis": len(with_diag),
        "bottlenecks_seen": sorted({r["diagnosis"]["bottleneck"]
                                    for r in with_diag}),
        "hint_records": len(hints),
        "hint_records_complete": len(complete),
        "hints_accepted": sum(1 for h in hints if h.get("accepted")),
    }


def main(ctx=None) -> Dict:
    ensure_ctx(ctx)     # table 10 owns its stores: legs must not share
    tmp = tempfile.mkdtemp(prefix="diag_demo_")
    print(f"# diagnosis demo: cases={CASES}, D={CFG.d_rounds}, "
          f"N={CFG.n_candidates}", flush=True)
    try:
        undiag = _leg("undiagnosed", diagnose=False,
                      executor=InProcessExecutor(1), tmp=tmp)
        diag = _leg("diagnosed", diagnose=True,
                    executor=InProcessExecutor(1), tmp=tmp)
        db_path = os.path.join(tmp, "db_sub.jsonl")
        sub = _leg("diagnosed-subprocess", diagnose=True,
                   executor=SubprocessExecutor(1), tmp=tmp,
                   store=PatternStore(os.path.join(tmp, "pat_sub.jsonl")),
                   db=ResultsDB(db_path))
        evidence = _journal_evidence(db_path)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    per_family: Dict[str, Dict] = {}
    for n in CASES:
        d, u = diag["cases"][n], undiag["cases"][n]
        fam = d["family"]
        f = per_family.setdefault(fam, {
            "cases": 0, "identical_winners": 0, "rtb_diag": 0,
            "rtb_undiag": 0, "evals_diag": 0, "evals_undiag": 0})
        f["cases"] += 1
        f["identical_winners"] += int(d["best_us"] == u["best_us"])
        f["rtb_diag"] += d["rounds_to_best"]
        f["rtb_undiag"] += u["rounds_to_best"]
        f["evals_diag"] += d["evals"]
        f["evals_undiag"] += u["evals"]
    improved = sorted(
        fam for fam, f in per_family.items()
        if f["identical_winners"] == f["cases"]
        and (f["rtb_diag"], f["evals_diag"])
        < (f["rtb_undiag"], f["evals_undiag"]))

    rec = {
        "table": "table10_diagnosis",
        "cases": CASES,
        "cfg": {"d_rounds": CFG.d_rounds, "n_candidates": CFG.n_candidates,
                "r": CFG.r, "k": CFG.k},
        "legs": {"undiagnosed": undiag, "diagnosed": diag,
                 "diagnosed_subprocess": sub},
        "per_family": per_family,
        "families_improved_identical_winner": improved,
        "rounds_to_best_reduction":
            undiag["total_rounds_to_best"] - diag["total_rounds_to_best"],
        "evals_reduction": undiag["total_evals"] - diag["total_evals"],
        "journal_evidence": evidence,
    }
    print(f"# table10_diagnosis: diagnosis cut rounds-to-best "
          f"{undiag['total_rounds_to_best']} -> "
          f"{diag['total_rounds_to_best']}, paid evals "
          f"{undiag['total_evals']} -> {diag['total_evals']}; families "
          f"improved w/ identical winner: {improved}; journal evidence: "
          f"{evidence['rounds_with_diagnosis']}/"
          f"{evidence['round_records']} rounds diagnosed, "
          f"{evidence['hint_records_complete']}/{evidence['hint_records']} "
          f"hint records complete", flush=True)
    out = os.path.join("results", "table10_diagnosis.json")
    try:
        os.makedirs("results", exist_ok=True)
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# wrote {out}", flush=True)
    except OSError:
        pass
    return rec


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", "src"))
    main()
